//! Portfolio meta-scheduler and lower-bound properties, end to end
//! through the scheduling service:
//!
//!  1. memory feasibility: on memory-tight clusters, every memory-aware
//!     algorithm's *valid* schedule respects every processor's memory;
//!  2. the portfolio's committed makespan is ≤ every candidate's σ=0
//!     simulated makespan (it is the argmin by construction — this
//!     pins the commit rule through the public batch API);
//!  3. the makespan lower bound is ≤ every algorithm's simulated
//!     makespan (the bound is sound against executions, not just
//!     against the analytic schedule);
//!  4. portfolio batches are byte-identical for any worker count and
//!     any score-thread count (the decision is replay-scored, so this
//!     pins that scoring happens off the parallel axes).

use std::sync::Arc;

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::{memory_constrained_cluster, small_cluster};
use memsched::scheduler::lower_bound::makespan_lower_bound;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::service::{
    self, ClusterSpec, Job, JobSource, SchedulingService, ScoreThreadSpec, ServiceConfig,
};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};
use memsched::testing::{check, random_cluster, random_dag};
use memsched::workflow::Workflow;

fn build(family: &str, input: usize, seed: u64) -> Workflow {
    WorkloadSpec { family: family.into(), size: None, input, seed }.build().unwrap()
}

/// σ=0 FollowStatic replay makespan of a schedule (NaN when invalid or
/// the execution does not complete).
fn replay_makespan(
    wf: &Workflow,
    cluster: &memsched::platform::Cluster,
    s: &memsched::scheduler::Schedule,
) -> f64 {
    if !s.valid {
        return f64::NAN;
    }
    let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::new(0.0, 0));
    let out = simulate(wf, cluster, s, &cfg);
    if out.completed {
        out.makespan
    } else {
        f64::NAN
    }
}

#[test]
fn all_memory_aware_algorithms_feasible_on_tight_clusters() {
    // Deterministic workloads on the paper's memory-constrained preset …
    let cluster = memory_constrained_cluster();
    for family in ["chipseq", "eager", "bacass"] {
        let wf = build(family, 1, 7);
        for algo in Algorithm::all().iter().copied().filter(|a| a.memory_aware()) {
            let s = ScheduleRequest::new(&wf, &cluster)
                .algo(algo)
                .policy(EvictionPolicy::LargestFirst)
                .run();
            if !s.valid {
                continue; // infeasible instances fall back to overcommit
            }
            for (j, &frac) in s.mem_peak_frac.iter().enumerate() {
                assert!(
                    frac <= 1.0 + 1e-9,
                    "{family}/{algo:?}: proc {j} peak {frac} exceeds memory on a valid schedule"
                );
            }
        }
    }
    // … and random DAGs on randomly tightened clusters.
    check(25, 0x7151, |rng| {
        let wf = random_dag(rng, 50);
        let cluster = random_cluster(rng).scale_memory(0.25, "tight-rand");
        for algo in Algorithm::all().iter().copied().filter(|a| a.memory_aware()) {
            let s = ScheduleRequest::new(&wf, &cluster)
                .algo(algo)
                .policy(EvictionPolicy::LargestFirst)
                .run();
            if !s.valid {
                continue;
            }
            for (j, &frac) in s.mem_peak_frac.iter().enumerate() {
                if frac > 1.0 + 1e-9 {
                    return Err(format!("{algo:?}: proc {j} peak {frac} on a valid schedule"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn portfolio_commits_the_minimum_simulated_makespan() {
    let cluster = Arc::new(small_cluster());
    let svc = SchedulingService::new(2);
    for (family, input, seed) in [("chipseq", 1, 3u64), ("eager", 2, 4), ("methylseq", 1, 6)] {
        let job = Job::new(
            JobSource::Generated(WorkloadSpec {
                family: family.into(),
                size: None,
                input,
                seed,
            }),
            ClusterSpec::Inline(cluster.clone()),
        )
        .with_algo(Algorithm::Portfolio);
        let r = &svc.run_batch(vec![job])[0];
        assert!(r.error.is_none(), "{family}: {:?}", r.error);
        let p = r.portfolio.as_ref().expect("portfolio rows carry the decision record");

        // Candidate scores match an independent out-of-service replay …
        let wf = build(family, input, seed);
        for c in &p.candidates {
            let s = ScheduleRequest::new(&wf, &cluster)
                .algo(c.algo)
                .policy(EvictionPolicy::LargestFirst)
                .run();
            assert_eq!(c.valid, s.valid, "{family}/{:?}: validity disagrees", c.algo);
            let expect = replay_makespan(&wf, &cluster, &s);
            assert!(
                (c.sim_makespan == expect) || (c.sim_makespan.is_nan() && expect.is_nan()),
                "{family}/{:?}: reported score {} != replay {expect}",
                c.algo,
                c.sim_makespan
            );
        }

        // … and the committed candidate is the argmin of those scores
        // (first wins on ties: no finite score strictly beats it, and no
        // earlier candidate matches it).
        let chosen_idx = p.candidates.iter().position(|c| c.algo == p.chosen).unwrap();
        let chosen = &p.candidates[chosen_idx];
        assert!(chosen.sim_makespan.is_finite(), "{family}: winner must have completed");
        for (i, c) in p.candidates.iter().enumerate() {
            if c.sim_makespan.is_finite() {
                assert!(
                    chosen.sim_makespan <= c.sim_makespan,
                    "{family}: candidate {:?} ({}) beats the committed {:?} ({})",
                    c.algo,
                    c.sim_makespan,
                    p.chosen,
                    chosen.sim_makespan
                );
                if i < chosen_idx {
                    assert!(
                        c.sim_makespan > chosen.sim_makespan,
                        "{family}: tie must break to the earlier candidate {:?}",
                        c.algo
                    );
                }
            }
        }
        assert_eq!(r.algo, Algorithm::Portfolio);
        assert!(r.valid && r.makespan.is_finite());
    }
}

#[test]
fn lower_bound_is_sound_against_simulated_executions() {
    for (family, input, seed) in [("chipseq", 1, 3u64), ("bacass", 0, 5), ("eager", 2, 4)] {
        let wf = build(family, input, seed);
        for cluster in [small_cluster(), memory_constrained_cluster()] {
            let lb = makespan_lower_bound(&wf, &cluster);
            assert!(lb > 0.0 && lb.is_finite(), "{family}/{}: bound {lb}", cluster.name);
            for &algo in Algorithm::all() {
                let s = ScheduleRequest::new(&wf, &cluster)
                    .algo(algo)
                    .policy(EvictionPolicy::LargestFirst)
                    .run();
                assert!(
                    s.makespan + 1e-9 >= lb,
                    "{family}/{}/{algo:?}: analytic makespan {} < bound {lb}",
                    cluster.name,
                    s.makespan
                );
                let sim = replay_makespan(&wf, &cluster, &s);
                if sim.is_finite() {
                    assert!(
                        sim + 1e-9 >= lb,
                        "{family}/{}/{algo:?}: simulated makespan {sim} < bound {lb}",
                        cluster.name
                    );
                }
            }
        }
    }
}

#[test]
fn portfolio_batches_are_byte_identical_across_parallelism_axes() {
    let cluster = ClusterSpec::Inline(Arc::new(small_cluster()));
    let jobs = |_: ()| -> Vec<Job> {
        let mut jobs = Vec::new();
        for (family, input, seed) in
            [("chipseq", 1, 3u64), ("eager", 2, 4), ("bacass", 0, 5), ("methylseq", 1, 6)]
        {
            let source = JobSource::Generated(WorkloadSpec {
                family: family.into(),
                size: None,
                input,
                seed,
            });
            jobs.push(
                Job::new(source.clone(), cluster.clone()).with_algo(Algorithm::Portfolio),
            );
            // A plain job on the same workload shares candidate schedules
            // through the cache without perturbing either row's bytes.
            jobs.push(Job::new(source, cluster.clone()).with_algo(Algorithm::HeftmBl));
        }
        // An exact duplicate: portfolio rows dedupe like any other job.
        let dup = jobs[0].clone();
        jobs.push(dup);
        jobs
    };

    let baseline = service::to_jsonl(&SchedulingService::new(1).run_batch(jobs(())));
    assert!(baseline.contains("\"portfolio\":{\"chosen\":"), "{baseline}");
    assert!(baseline.contains("\"optimality_gap\":"), "{baseline}");
    for workers in [4usize, 8] {
        let out = service::to_jsonl(&SchedulingService::new(workers).run_batch(jobs(())));
        assert_eq!(baseline, out, "portfolio JSONL diverged at --jobs {workers}");
    }
    for score_threads in [1usize, 8] {
        let svc = SchedulingService::from_config(ServiceConfig {
            workers: 4,
            score: ScoreThreadSpec::Fixed(score_threads),
            ..ServiceConfig::default()
        })
        .unwrap();
        let out = service::to_jsonl(&svc.run_batch(jobs(())));
        assert_eq!(
            baseline, out,
            "portfolio JSONL diverged at --score-threads {score_threads}"
        );
    }
    // The duplicate committed identical bytes apart from id/cache_hit.
    let lines: Vec<&str> = baseline.lines().collect();
    let first = lines[0];
    let dup = lines[lines.len() - 1];
    let payload = |l: &str| l.split_once("\"valid\"").unwrap().1.to_string();
    assert_eq!(payload(first), payload(dup), "deduped portfolio rows must agree");
}
