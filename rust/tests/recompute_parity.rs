//! Bit-level parity of the adaptive-recompute fast path: pooled
//! mid-run rescheduling and scaffold-hoisted selector state must leave
//! every Recompute-mode outcome byte-identical to the serial,
//! per-trigger-rebuild baseline.
//!
//! Three layers, mirroring `scoring_parity.rs` for the static engine:
//! 1. `SimRun::simulate_with` — serial vs `ScorePool` of 2/4/8 threads,
//!    per algorithm × sigma;
//! 2. hoisted selector state (`SimScaffold::selector`, built once per
//!    scaffold from estimates) vs a fresh `SelectorState` per trigger;
//! 3. the service batch JSONL — whole-stream byte compare across
//!    `--score-threads` values (the `ci.sh --smoke` check, in-process).

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::small_cluster;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::service::{
    ClusterSpec, Job, JobSource, ScoreThreadSpec, ScorePool, SchedulingService, ServiceConfig,
    SimJob,
};
use memsched::simulator::{DeviationModel, SimConfig, SimMode, SimOutcome, SimRun, SimScaffold};
use std::sync::Arc;

/// Full-outcome bit digest: every per-task finish time, the makespan,
/// and the integer counters.
fn outcome_bits(out: &SimOutcome) -> (bool, Vec<u64>, u64, usize, usize) {
    (
        out.completed,
        out.finish_times.iter().map(|f| f.to_bits()).collect(),
        out.makespan.to_bits(),
        out.recomputations,
        out.started,
    )
}

fn scaffold_for(algo: Algorithm, tasks: usize) -> Option<SimScaffold> {
    let spec = WorkloadSpec { family: "chipseq".into(), size: Some(tasks), input: 2, seed: 7 };
    let wf = spec.build().expect("generated workload builds");
    let cluster = small_cluster();
    let schedule = ScheduleRequest::new(&wf, &cluster)
        .algo(algo)
        .policy(EvictionPolicy::LargestFirst)
        .run();
    if !schedule.valid {
        return None;
    }
    Some(SimScaffold::new(Arc::new(wf), Arc::new(cluster), Arc::new(schedule)))
}

#[test]
fn pooled_recompute_parity_across_thread_counts() {
    for &algo in Algorithm::all() {
        let Some(scaffold) = scaffold_for(algo, 300) else { continue };
        for sigma in [0.1, 0.3] {
            let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(sigma, 9));
            let mut run = SimRun::new();
            let base = outcome_bits(&run.simulate_with(&scaffold, &cfg, None));
            for threads in [2usize, 4, 8] {
                let pool = ScorePool::new(threads);
                let mut run = SimRun::new();
                let pooled = outcome_bits(&run.simulate_with(&scaffold, &cfg, Some(&pool)));
                assert_eq!(
                    base, pooled,
                    "{algo:?}/sigma={sigma} diverged at --score-threads {threads}"
                );
            }
        }
    }
}

#[test]
fn hoisted_selector_parity_with_per_trigger_rebuild() {
    // The selector-heavy algorithms: PEFT's OCT table, Lookahead's and
    // DLS's rank inputs are what the scaffold hoists.
    for algo in [Algorithm::Peft, Algorithm::Lookahead, Algorithm::Dls, Algorithm::HeftmBl] {
        let Some(scaffold) = scaffold_for(algo, 300) else { continue };
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, 9));
        let mut hoisted_run = SimRun::new();
        let hoisted = outcome_bits(&hoisted_run.simulate_with(&scaffold, &cfg, None));
        let mut rebuilt_run = SimRun::new();
        rebuilt_run.set_rebuild_selector(true);
        let rebuilt = outcome_bits(&rebuilt_run.simulate_with(&scaffold, &cfg, None));
        assert_eq!(hoisted, rebuilt, "{algo:?}: hoisted selector state changed the outcome");
    }
}

#[test]
fn recompute_batch_bytes_identical_across_score_threads() {
    let cluster = Arc::new(small_cluster());
    let jobs = || -> Vec<Job> {
        let mut jobs = Vec::new();
        for &algo in Algorithm::all() {
            for sigma in [0.1, 0.3] {
                let spec = WorkloadSpec { family: "chipseq".into(), size: None, input: 1, seed: 5 };
                jobs.push(
                    Job::new(JobSource::Generated(spec), ClusterSpec::Inline(cluster.clone()))
                        .with_algo(algo)
                        .with_sim(SimJob { mode: SimMode::Recompute, sigma, seed: 9 }),
                );
            }
        }
        jobs
    };
    let batch_bytes = |threads: usize| -> String {
        let svc = SchedulingService::from_config(ServiceConfig {
            workers: 2,
            score: ScoreThreadSpec::Fixed(threads),
            ..ServiceConfig::default()
        })
        .unwrap();
        svc.run_batch(jobs()).iter().map(|r| r.to_jsonl() + "\n").collect()
    };
    let serial = batch_bytes(1);
    for threads in [2usize, 4] {
        assert_eq!(
            serial,
            batch_bytes(threads),
            "batch JSONL diverged at --score-threads {threads}"
        );
    }
}
