//! End-to-end acceptance tests for the replay engine + disk-backed
//! schedule cache (ISSUE 4): at smoke scale, a multi-sigma dynamic sweep
//! computes each static schedule exactly once, and its JSONL output is
//! byte-identical to the per-sigma/per-point baseline across `--jobs
//! 1/4` and warm/cold `--cache-dir`.

use memsched::experiments::{dynamic_suite_specs, dynamic_suite_sweeps, SuiteScale};
use memsched::platform::presets::small_cluster;
use memsched::scheduler::Algorithm;
use memsched::service::{
    to_jsonl, ClusterSpec, Job, ReplaySweep, SchedulingService, ScoreThreadSpec, ServiceConfig,
};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A service with a disk-backed schedule cache at `dir` (the
/// `ServiceConfig`-only construction surface).
fn disk_svc(workers: usize, dir: &Path) -> SchedulingService {
    SchedulingService::from_config(ServiceConfig {
        workers,
        cache_dir: Some(dir.to_path_buf()),
        ..ServiceConfig::default()
    })
    .unwrap()
}

const SIGMAS: [f64; 2] = [0.1, 0.3];

fn smoke_sweeps() -> Vec<ReplaySweep> {
    let specs = dynamic_suite_specs(SuiteScale::Smoke, 7);
    let cluster = ClusterSpec::Inline(Arc::new(small_cluster()));
    dynamic_suite_sweeps(&specs, &cluster, &SIGMAS)
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("memsched_replay_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn multi_sigma_sweep_schedules_once_and_matches_flat_baseline() {
    let sweeps = smoke_sweeps();
    let n_schedules = sweeps.len(); // one (workload, algorithm) cell each
    let n_points: usize = sweeps.iter().map(ReplaySweep::num_results).sum();
    assert_eq!(n_points, n_schedules * SIGMAS.len() * 2);

    // Baseline: the flattened per-point jobs through the plain batch API.
    let flat: Vec<Job> = sweeps.iter().flat_map(|s| s.flatten()).collect();
    let baseline = to_jsonl(&SchedulingService::new(1).run_batch(flat));

    // The replay engine, across worker counts: byte-identical output,
    // each static schedule computed exactly once.
    for workers in [1, 4] {
        let svc = SchedulingService::new(workers);
        let out = to_jsonl(&svc.run_replay_sweeps(sweeps.clone()));
        assert_eq!(out, baseline, "sweep output must match the flat baseline at jobs={workers}");
        let stats = svc.cache_stats();
        assert_eq!(stats.computed, n_schedules, "one schedule per sweep at jobs={workers}");
        assert_eq!(stats.lookups, n_points);
        assert_eq!(stats.hits(), n_points - n_schedules);
    }
}

#[test]
fn warm_and_cold_cache_dir_keep_sweep_bytes_identical() {
    let dir = temp_dir("warmcold");
    let sweeps = smoke_sweeps();
    let n_schedules = sweeps.len();
    let no_cache = to_jsonl(&SchedulingService::new(4).run_replay_sweeps(sweeps.clone()));

    // Cold disk cache: everything computed, everything persisted.
    let cold = disk_svc(4, &dir);
    let cold_out = to_jsonl(&cold.run_replay_sweeps(sweeps.clone()));
    assert_eq!(cold_out, no_cache, "a cold cache dir must not change output bytes");
    assert_eq!(cold.cache_stats().computed, n_schedules);
    assert_eq!(cold.cache_stats().disk_hits, 0);

    // Warm disk cache in a fresh service ("second CLI invocation"):
    // zero schedules computed, byte-identical results — across both
    // worker counts.
    for workers in [1, 4] {
        let warm = disk_svc(workers, &dir);
        let warm_out = to_jsonl(&warm.run_replay_sweeps(sweeps.clone()));
        assert_eq!(warm_out, no_cache, "warm cache dir must not change output bytes");
        let stats = warm.cache_stats();
        assert_eq!(stats.computed, 0, "warm run must compute nothing (jobs={workers})");
        assert_eq!(stats.disk_hits, n_schedules);
        // The summary record surfaces exactly these counters for ci.sh.
        let line = warm.summary_json(0, 0, 0).to_string_compact();
        assert!(line.contains("\"schedules_computed\":0"), "{line}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweeps_with_auto_score_threads_match_serial_bytes() {
    let sweeps = smoke_sweeps();
    let cfg = |score| ServiceConfig { workers: 2, score, ..ServiceConfig::default() };
    let serial = to_jsonl(
        &SchedulingService::from_config(cfg(ScoreThreadSpec::Fixed(1)))
            .unwrap()
            .run_replay_sweeps(sweeps.clone()),
    );
    let auto = to_jsonl(
        &SchedulingService::from_config(cfg(ScoreThreadSpec::Auto))
            .unwrap()
            .run_replay_sweeps(sweeps),
    );
    assert_eq!(serial, auto, "auto score threads must preserve bytes");
}

#[test]
fn corrupted_store_recovers_per_entry() {
    // Corrupt a subset of a warm store's entries: corrupted fingerprints
    // recompute, intact ones load, results stay byte-identical.
    let dir = temp_dir("repair");
    let sweeps = smoke_sweeps();
    let n_schedules = sweeps.len();
    let cold = disk_svc(2, &dir);
    let expected = to_jsonl(&cold.run_replay_sweeps(sweeps.clone()));

    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "sched"))
        .collect();
    entries.sort();
    assert_eq!(entries.len(), n_schedules);
    // Damage three entries three different ways.
    let full = std::fs::read(&entries[0]).unwrap();
    std::fs::write(&entries[0], &full[..full.len() / 3]).unwrap(); // truncated
    let mut versioned = std::fs::read(&entries[1]).unwrap();
    versioned[8] ^= 0x55; // wrong version header
    std::fs::write(&entries[1], versioned).unwrap();
    std::fs::write(&entries[2], b"fingerprint-collision-shaped garbage").unwrap();

    let repaired = disk_svc(2, &dir);
    let out = to_jsonl(&repaired.run_replay_sweeps(sweeps.clone()));
    assert_eq!(out, expected, "corruption must never change results");
    let stats = repaired.cache_stats();
    assert_eq!(stats.computed, 3, "exactly the corrupted entries recompute");
    assert_eq!(stats.disk_hits, n_schedules - 3);

    // The recomputes re-persisted their entries: a third pass is fully warm.
    let warm = disk_svc(2, &dir);
    assert_eq!(to_jsonl(&warm.run_replay_sweeps(sweeps)), expected);
    assert_eq!(warm.cache_stats().computed, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mixed_static_and_sweep_batches_stream_in_order() {
    // A sweep batch that mixes point-less (static) sweeps with replay
    // sweeps and a failing sweep: ids stay sequential over the flattened
    // stream and match the flat-path bytes.
    let cluster = ClusterSpec::Inline(Arc::new(small_cluster()));
    let specs = dynamic_suite_specs(SuiteScale::Smoke, 3);
    let mut sweeps = dynamic_suite_sweeps(&specs[..2], &cluster, &[0.2]);
    sweeps.push(
        ReplaySweep::new(
            memsched::service::JobSource::Generated(memsched::experiments::WorkloadSpec {
                family: specs[0].family.clone(),
                size: None,
                input: specs[0].input,
                seed: specs[0].seed,
            }),
            cluster.clone(),
        )
        .with_algo(Algorithm::Heft),
    );
    sweeps.push(ReplaySweep::new(
        memsched::service::JobSource::Generated(memsched::experiments::WorkloadSpec {
            family: "no_such_family".into(),
            size: None,
            input: 0,
            seed: 1,
        }),
        cluster,
    ));
    let flat: Vec<Job> = sweeps.iter().flat_map(|s| s.flatten()).collect();
    let svc = SchedulingService::new(3);
    let results = svc.run_replay_sweeps(sweeps);
    assert_eq!(results.len(), flat.len());
    assert!(results.iter().enumerate().all(|(i, r)| r.id == i));
    assert!(results.last().unwrap().error.as_deref().unwrap().contains("no_such_family"));
    let baseline = SchedulingService::new(1).run_batch(flat);
    assert_eq!(to_jsonl(&results), to_jsonl(&baseline));
}
