//! Parity contract of the shared simulation scaffold (the replay core):
//!
//! 1. `SimScaffold` + a reused `SimRun` arena produce **bit-equal**
//!    `SimOutcome`s (makespan, recomputations, finish_times, failure) to
//!    a point-by-point `simulate()` loop, across both `SimMode`s and
//!    several sigmas;
//! 2. the service's scaffold-backed replay-sweep path emits
//!    **byte-identical** sweep JSONL to the flattened per-point batch,
//!    for `--jobs 1` and `--jobs 4`, and its per-point sim fields are
//!    bit-equal to direct `simulate()` ground truth;
//! 3. the scaffold is built exactly once per sweep (the acceptance
//!    counter surfaced in the run summary).

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::small_cluster;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::service::{
    to_jsonl, ClusterSpec, Job, JobSource, ReplaySweep, SchedulingService, ScoreThreadSpec,
    ServiceConfig, SimJob,
};
use memsched::simulator::{
    simulate, DeviationModel, EventQueueKind, SimConfig, SimMode, SimOutcome, SimRun, SimScaffold,
};
use std::sync::Arc;

const SIGMAS: [f64; 2] = [0.1, 0.3];
const MODES: [SimMode; 2] = [SimMode::Recompute, SimMode::FollowStatic];
const DEV_SEED: u64 = 9;

fn spec() -> WorkloadSpec {
    // The same instance `experiments::tests::dynamic_run_smoke` asserts
    // schedules validly on `small_cluster` — the parity tests below rely
    // on the schedules being valid so the replay points actually run.
    WorkloadSpec { family: "chipseq".into(), size: None, input: 0, seed: 3 }
}

fn points() -> Vec<SimJob> {
    SIGMAS
        .into_iter()
        .flat_map(|sigma| MODES.into_iter().map(move |mode| SimJob { mode, sigma, seed: DEV_SEED }))
        .collect()
}

fn outcomes_bit_equal(a: &SimOutcome, b: &SimOutcome, ctx: &str) {
    assert_eq!(a.completed, b.completed, "{ctx}: completed");
    assert_eq!(a.failure, b.failure, "{ctx}: failure");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{ctx}: makespan");
    assert_eq!(a.recomputations, b.recomputations, "{ctx}: recomputations");
    assert_eq!(a.started, b.started, "{ctx}: started");
    assert_eq!(
        a.finish_times.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
        b.finish_times.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
        "{ctx}: finish_times"
    );
}

#[test]
fn scaffold_outcomes_bit_equal_point_by_point_simulate() {
    let wf = spec().build().unwrap();
    let cluster = small_cluster();
    for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
        let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid, "{algo:?} schedule must be valid for this parity test");
        let scaffold = SimScaffold::new(
            Arc::new(wf.clone()),
            Arc::new(cluster.clone()),
            Arc::new(s.clone()),
        );
        // One arena across every point — the sweep execution shape.
        let mut run = SimRun::new();
        for point in points() {
            let cfg = SimConfig::new(point.mode, DeviationModel::new(point.sigma, point.seed));
            let fresh = simulate(&wf, &cluster, &s, &cfg);
            let reused = run.simulate(&scaffold, &cfg);
            outcomes_bit_equal(
                &fresh,
                &reused,
                &format!("{algo:?} {:?} sigma={}", point.mode, point.sigma),
            );
        }
    }
}

#[test]
fn calendar_event_queue_bit_equal_across_modes_and_sigmas() {
    // The event-queue choice is a pure implementation detail: the
    // calendar variant must replay every (mode, sigma) point bit-equal
    // to both the heap-backed arena and a fresh `simulate()`.
    let wf = spec().build().unwrap();
    let cluster = small_cluster();
    for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
        let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let scaffold = SimScaffold::new(
            Arc::new(wf.clone()),
            Arc::new(cluster.clone()),
            Arc::new(s.clone()),
        );
        let mut run = SimRun::new();
        run.set_event_queue(EventQueueKind::Calendar);
        assert_eq!(run.event_queue_kind(), EventQueueKind::Calendar);
        for point in points() {
            let cfg = SimConfig::new(point.mode, DeviationModel::new(point.sigma, point.seed));
            let fresh = simulate(&wf, &cluster, &s, &cfg);
            let reused = run.simulate(&scaffold, &cfg);
            outcomes_bit_equal(
                &fresh,
                &reused,
                &format!("calendar {algo:?} {:?} sigma={}", point.mode, point.sigma),
            );
        }
    }
}

fn sweeps(cluster: &Arc<memsched::platform::Cluster>) -> Vec<ReplaySweep> {
    [Algorithm::HeftmBl, Algorithm::HeftmMm]
        .into_iter()
        .map(|algo| {
            ReplaySweep::new(
                JobSource::Generated(spec()),
                ClusterSpec::Inline(cluster.clone()),
            )
            .with_algo(algo)
            .with_points(points())
        })
        .collect()
}

#[test]
fn sweep_jsonl_bytes_identical_across_jobs_and_to_flat_batch() {
    let cluster = Arc::new(small_cluster());
    let flattened: Vec<Job> = sweeps(&cluster).iter().flat_map(|s| s.flatten()).collect();

    let svc1 = SchedulingService::new(1);
    let mut jobs1 = Vec::new();
    svc1.run_replay_sweeps_streaming(sweeps(&cluster), |r| jobs1.push(r));
    let svc4 = SchedulingService::new(4);
    let mut jobs4 = Vec::new();
    svc4.run_replay_sweeps_streaming(sweeps(&cluster), |r| jobs4.push(r));
    assert_eq!(to_jsonl(&jobs1), to_jsonl(&jobs4), "sweep JSONL must not depend on --jobs");

    let flat = SchedulingService::new(1).run_batch(flattened);
    assert_eq!(
        to_jsonl(&jobs1),
        to_jsonl(&flat),
        "scaffold-backed sweep path must match the per-point batch byte for byte"
    );

    // Acceptance counter: one scaffold per sweep, at any worker count.
    assert_eq!(svc1.scaffolds_built(), 2);
    assert_eq!(svc4.scaffolds_built(), 2);

    // Per-worker score pools (the `--score-pools` contention relief)
    // must not perturb a single byte either.
    let pooled = SchedulingService::from_config(ServiceConfig {
        workers: 4,
        score: ScoreThreadSpec::Fixed(2),
        score_pools: 2,
        ..ServiceConfig::default()
    })
    .unwrap();
    let mut jobs_pooled = Vec::new();
    pooled.run_replay_sweeps_streaming(sweeps(&cluster), |r| jobs_pooled.push(r));
    assert_eq!(
        to_jsonl(&jobs1),
        to_jsonl(&jobs_pooled),
        "sweep JSONL must not depend on --score-pools"
    );
}

#[test]
fn sweep_sim_fields_bit_equal_direct_simulate_ground_truth() {
    let cluster = Arc::new(small_cluster());
    let svc = SchedulingService::new(4);
    let results = svc.run_replay_sweeps(sweeps(&cluster));
    assert!(results.iter().all(|r| r.error.is_none()));

    let wf = spec().build().unwrap();
    let mut it = results.iter();
    for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
        let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        for point in points() {
            let r = it.next().expect("one result per point");
            assert_eq!(r.algo, algo);
            let sim = r.sim.as_ref().expect("replay points carry sim results");
            let cfg = SimConfig::new(point.mode, DeviationModel::new(point.sigma, point.seed));
            let truth = simulate(&wf, &cluster, &s, &cfg);
            let ctx = format!("{algo:?} {:?} sigma={}", point.mode, point.sigma);
            assert_eq!(sim.mode, point.mode, "{ctx}");
            assert_eq!(sim.completed, truth.completed, "{ctx}");
            assert_eq!(sim.makespan.to_bits(), truth.makespan.to_bits(), "{ctx}");
            assert_eq!(sim.recomputations, truth.recomputations, "{ctx}");
            assert_eq!(sim.started, truth.started, "{ctx}");
        }
    }
    assert!(it.next().is_none(), "no extra results");
}
