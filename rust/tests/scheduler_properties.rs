//! Property-based tests over the scheduler invariants, on random DAGs and
//! random heterogeneous clusters (in-tree mini-framework; see
//! `memsched::testing`).
//!
//! Invariants checked:
//!  1. every schedule places every task exactly once;
//!  2. precedence: a child never starts before its parent's finish plus
//!     the cross-processor communication time;
//!  3. exclusivity: tasks on one processor never overlap;
//!  4. memory: valid memory-aware schedules never exceed any processor's
//!     memory (peak fraction ≤ 1) nor its communication buffer;
//!  5. the independent retrace oracle agrees that valid schedules are
//!     valid under unchanged parameters, and reproduces finish times.

use memsched::scheduler::{retrace, Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::testing::{check, random_cluster, random_dag};

const CASES: usize = 60;

#[test]
fn schedules_are_complete_and_precedence_safe() {
    check(CASES, 0xA11CE, |rng| {
        let wf = random_dag(rng, 80);
        let cluster = random_cluster(rng);
        for &algo in Algorithm::all() {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if s.tasks.len() != wf.num_tasks() {
                return Err(format!("{algo:?}: incomplete schedule"));
            }
            for e in wf.edges() {
                let (ts, td) = (&s.tasks[e.src], &s.tasks[e.dst]);
                let comm = cluster.comm_time(e.data, ts.proc, td.proc);
                if td.start + 1e-6 < ts.finish + comm {
                    return Err(format!(
                        "{algo:?}: edge ({},{}) violated: child {} < parent {} + comm {comm}",
                        e.src, e.dst, td.start, ts.finish
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn processor_exclusivity() {
    check(CASES, 0xB0B, |rng| {
        let wf = random_dag(rng, 60);
        let cluster = random_cluster(rng);
        for &algo in Algorithm::all() {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            let mut by_proc: std::collections::HashMap<usize, Vec<(f64, f64)>> =
                Default::default();
            for t in &s.tasks {
                by_proc.entry(t.proc).or_default().push((t.start, t.finish));
            }
            for (p, mut iv) in by_proc {
                iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                for w in iv.windows(2) {
                    if w[0].1 > w[1].0 + 1e-6 {
                        return Err(format!(
                            "{algo:?}: overlap on proc {p}: {:?} vs {:?}",
                            w[0], w[1]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn valid_memory_aware_schedules_never_exceed_memory() {
    check(CASES, 0xCAFE, |rng| {
        let wf = random_dag(rng, 60);
        let cluster = random_cluster(rng);
        // Every memory-aware algorithm, PEFT/Lookahead/DLS included —
        // a new variant cannot silently skip this invariant.
        for algo in Algorithm::all().iter().copied().filter(|a| a.memory_aware()) {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if !s.valid {
                continue; // invalid schedules may overcommit via fallback
            }
            for (j, &frac) in s.mem_peak_frac.iter().enumerate() {
                if frac > 1.0 + 1e-9 {
                    return Err(format!(
                        "{algo:?}: proc {j} peak {frac} exceeds memory on a valid schedule"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn retrace_oracle_confirms_valid_schedules() {
    check(CASES, 0xD0E, |rng| {
        let wf = random_dag(rng, 50);
        let cluster = random_cluster(rng);
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if !s.valid {
                continue;
            }
            let r = retrace::retrace(&wf, &cluster, &s, EvictionPolicy::LargestFirst, &[]);
            if !r.valid {
                return Err(format!(
                    "{algo:?}: retrace rejected an unchanged valid schedule: {:?} at {:?}",
                    r.failure, r.failed_task
                ));
            }
            let rel = (r.makespan - s.makespan).abs() / s.makespan.max(1e-9);
            if rel > 1e-6 {
                return Err(format!(
                    "{algo:?}: retrace makespan {} != schedule {}",
                    r.makespan, s.makespan
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn heft_never_beats_itself_with_memory_awareness_disabled_check() {
    // HEFT ignores memory, so its makespan is a lower bound for HEFTM-BL
    // (same ranking, strictly fewer feasible choices per step is not a
    // theorem for list schedulers, but a large systematic win would
    // indicate a bookkeeping bug; allow 1% tolerance).
    check(CASES, 0xFEED, |rng| {
        let wf = random_dag(rng, 60);
        let cluster = random_cluster(rng);
        let heft = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Heft).policy(EvictionPolicy::LargestFirst).run();
        let bl = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        if bl.valid && heft.valid && bl.makespan < heft.makespan * 0.9 {
            return Err(format!(
                "HEFTM-BL {} dramatically beats HEFT {} — suspicious",
                bl.makespan, heft.makespan
            ));
        }
        Ok(())
    });
}

#[test]
fn eviction_policies_both_produce_valid_schedules() {
    check(CASES, 0x5EED, |rng| {
        let wf = random_dag(rng, 50);
        let cluster = random_cluster(rng);
        let a = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        let b = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::SmallestFirst).run();
        // The paper reports comparable results; at minimum validity must
        // agree in the vast majority of cases. We only require: if one is
        // valid, makespans stay within 2x of each other when both valid.
        if a.valid && b.valid {
            let ratio = a.makespan / b.makespan;
            if !(0.5..=2.0).contains(&ratio) {
                return Err(format!("policy divergence: {} vs {}", a.makespan, b.makespan));
            }
        }
        Ok(())
    });
}

#[test]
fn schedules_deterministic() {
    check(20, 0xDEAD, |rng| {
        let wf = random_dag(rng, 40);
        let cluster = random_cluster(rng);
        for &algo in Algorithm::all() {
            let a = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            let b = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if a.makespan != b.makespan || a.valid != b.valid {
                return Err(format!("{algo:?} nondeterministic"));
            }
            for (x, y) in a.tasks.iter().zip(&b.tasks) {
                if x != y {
                    return Err(format!("{algo:?} placement nondeterminism"));
                }
            }
        }
        Ok(())
    });
}
