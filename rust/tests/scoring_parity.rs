//! Byte-level parity of parallel intra-schedule scoring: for any
//! `--score-threads` count, every algorithm and eviction policy must
//! produce a schedule *bit-identical* to the serial engine's on generated
//! 1k-task DAGs — placements, start/finish times (f64 bits), eviction
//! lists, rank order, validity, and peak-memory fractions.
//!
//! This is the engine-level counterpart of `service_determinism.rs`
//! (which checks the batch JSONL): the deterministic reduction in
//! `Engine::assign` (min finish time, ties to the lowest ProcId) is what
//! both guarantees rest on.

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::{memory_constrained_cluster, small_cluster};
use memsched::platform::Cluster;
use memsched::scheduler::{Algorithm, Engine, EvictionPolicy, Schedule};
use memsched::service::ScorePool;
use memsched::workflow::Workflow;

/// Canonical byte encoding of everything a schedule decides.
fn schedule_bytes(s: &Schedule) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(s.valid as u8);
    out.extend((s.failures.len() as u64).to_le_bytes());
    out.extend((s.rank_order.len() as u64).to_le_bytes());
    for &t in &s.rank_order {
        out.extend((t as u64).to_le_bytes());
    }
    for t in &s.tasks {
        out.extend((t.proc as u64).to_le_bytes());
        out.extend(t.start.to_bits().to_le_bytes());
        out.extend(t.finish.to_bits().to_le_bytes());
        out.extend((t.evicted.len() as u64).to_le_bytes());
        for &e in &t.evicted {
            out.extend((e as u64).to_le_bytes());
        }
        out.push(t.res_nonneg as u8);
    }
    out.extend(s.makespan.to_bits().to_le_bytes());
    for &f in &s.mem_peak_frac {
        out.extend(f.to_bits().to_le_bytes());
    }
    out
}

fn workload(family: &str, tasks: usize, input: usize, seed: u64) -> Workflow {
    WorkloadSpec { family: family.into(), size: Some(tasks), input, seed }
        .build()
        .expect("generated workload builds")
}

fn assert_parity(wf: &Workflow, cluster: &Cluster, algos: &[Algorithm], label: &str) {
    for &algo in algos {
        for policy in [EvictionPolicy::LargestFirst, EvictionPolicy::SmallestFirst] {
            let order = algo.rank_order(wf, cluster);
            let serial = Engine::new(wf, cluster, algo, policy).run(&order);
            let serial_bytes = schedule_bytes(&serial);
            for threads in [2usize, 4, 8] {
                let pool = ScorePool::new(threads);
                let parallel = Engine::new(wf, cluster, algo, policy)
                    .with_parallel_scoring(&pool)
                    .run(&order);
                assert_eq!(
                    serial_bytes,
                    schedule_bytes(&parallel),
                    "{label}: {algo:?}/{policy:?} diverged at --score-threads {threads}"
                );
            }
        }
    }
}

#[test]
fn parallel_scoring_parity_on_eviction_heavy_1k_dags() {
    // A tight small cluster: plenty of Step-1 rejections, evictions, and
    // out-of-memory fallbacks — the paths where nondeterminism would hide.
    let cluster = small_cluster().scale_memory(0.03, "tight-small");
    let wf = workload("chipseq", 1000, 3, 11);
    assert_parity(&wf, &cluster, Algorithm::all(), "chipseq-1k/tight");
}

#[test]
fn parallel_scoring_parity_on_second_family() {
    let cluster = small_cluster().scale_memory(0.05, "tight-small-2");
    let wf = workload("eager", 1000, 2, 23);
    assert_parity(&wf, &cluster, Algorithm::all(), "eager-1k/tight");
}

#[test]
fn parallel_scoring_parity_on_wide_cluster() {
    // The paper's 72-processor memory-constrained cluster: wide chunked
    // fan-out (the configuration bench_engine measures).
    let cluster = memory_constrained_cluster();
    let wf = workload("methylseq", 1000, 3, 5);
    assert_parity(
        &wf,
        &cluster,
        &[Algorithm::Heft, Algorithm::HeftmBl],
        "methylseq-1k/wide",
    );
}
