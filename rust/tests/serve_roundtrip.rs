//! End-to-end acceptance tests for `memsched serve` (ISSUE 6): clients
//! talking length-delimited frames to a live daemon over a Unix socket
//! get responses **byte-identical** to `memsched batch` on the same job
//! lines; a warm second client computes zero schedules; malformed and
//! oversized frames degrade per-connection, never the process; and a
//! shutdown request drains queued work before the daemon returns.

use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use memsched::ser::frame::{read_frame, write_frame, DEFAULT_MAX_FRAME_BYTES};
use memsched::service::serve::{serve_unix, ServeSummary};
use memsched::service::{
    to_jsonl, JobSpec, ParseDefaults, SchedulingService, ServeOptions,
};

fn socket_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("memsched_serve_it_{tag}_{}.sock", std::process::id()))
}

/// Start a daemon on `path` in a background thread; returns its join
/// handle (the serve summary plus the service's computed-schedule
/// count).
fn spawn_daemon(
    path: PathBuf,
    opts: ServeOptions,
    workers: usize,
) -> std::thread::JoinHandle<(ServeSummary, usize)> {
    std::thread::spawn(move || {
        let svc = SchedulingService::new(workers);
        let summary = serve_unix(&svc, &path, &opts).expect("serve_unix failed");
        (summary, svc.cache_stats().computed)
    })
}

fn connect(path: &Path) -> UnixStream {
    for _ in 0..500 {
        if let Ok(s) = UnixStream::connect(path) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("serve socket {} never appeared", path.display());
}

/// A test client: send raw payloads, receive raw payloads.
struct Client {
    stream: UnixStream,
}

impl Client {
    fn new(path: &Path) -> Client {
        Client { stream: connect(path) }
    }

    fn send(&mut self, payload: &str) {
        write_frame(&mut self.stream, payload.as_bytes()).unwrap();
    }

    fn recv(&mut self) -> Option<String> {
        read_frame(&mut self.stream, DEFAULT_MAX_FRAME_BYTES)
            .expect("client-side frame decode failed")
            .map(|p| String::from_utf8(p).expect("non-UTF-8 frame payload"))
    }

    /// Send a drain barrier and collect everything up to its ack:
    /// (result lines, error frames).
    fn drain(&mut self) -> (Vec<String>, Vec<String>) {
        self.send(r#"{"ctl":"drain"}"#);
        let (mut results, mut errors) = (Vec::new(), Vec::new());
        loop {
            let frame = self.recv().expect("connection closed before the drain ack");
            if frame == r#"{"ok":"drained"}"# {
                return (results, errors);
            }
            // Result lines always lead with their id; error frames
            // (`{"error":...}`) have no id.
            if frame.starts_with("{\"id\":") {
                results.push(frame);
            } else {
                errors.push(frame);
            }
        }
    }
}

/// What `memsched batch` emits for these lines on a cold service —
/// the byte-level contract every serve response must match.
fn batch_baseline(lines: &[&str]) -> String {
    let defaults = ParseDefaults::default();
    let sweeps = lines
        .iter()
        .map(|l| JobSpec::parse_line(l, &defaults).unwrap().into_sweep())
        .collect();
    to_jsonl(&SchedulingService::new(1).run_replay_sweeps(sweeps))
}

fn joined(results: &[String]) -> String {
    results.iter().map(|r| format!("{r}\n")).collect()
}

const LINES_A: [&str; 3] = [
    r#"{"model":"bacass","input":1,"seed":5}"#,
    r#"{"model":"bacass","input":1,"seed":5,"algo":"heftm-mm"}"#,
    // Duplicate of the first line: an intra-client cache_hit.
    r#"{"model":"bacass","input":1,"seed":5}"#,
];

const LINES_B: [&str; 2] = [
    r#"{"model":"chipseq","input":0,"seed":7}"#,
    r#"{"model":"chipseq","input":0,"seed":7,"sweep":[{"mode":"recompute","seed":9},{"mode":"static","seed":9}]}"#,
];

#[test]
fn interleaved_clients_match_batch_bytes_and_warm_client_computes_nothing() {
    let path = socket_path("roundtrip");
    let daemon = spawn_daemon(path.clone(), ServeOptions::default(), 2);

    let expected_a = batch_baseline(&LINES_A);
    let expected_b = batch_baseline(&LINES_B);

    // Two clients interleave their submissions frame by frame; each
    // stream must come back byte-identical to its own cold batch.
    let mut a = Client::new(&path);
    a.send(r#"{"ctl":"ping"}"#);
    assert_eq!(a.recv().as_deref(), Some(r#"{"ok":"pong"}"#));
    let mut b = Client::new(&path);
    for i in 0..LINES_A.len().max(LINES_B.len()) {
        if let Some(line) = LINES_A.get(i) {
            a.send(line);
        }
        if let Some(line) = LINES_B.get(i) {
            b.send(line);
        }
    }
    let (results_a, errors_a) = a.drain();
    let (results_b, errors_b) = b.drain();
    assert!(errors_a.is_empty(), "{errors_a:?}");
    assert!(errors_b.is_empty(), "{errors_b:?}");
    assert_eq!(joined(&results_a), expected_a, "client A must match its cold batch");
    assert_eq!(joined(&results_b), expected_b, "client B must match its cold batch");
    drop(a);
    drop(b);

    // A third client re-submits A's lines against the now-warm daemon:
    // same bytes, zero schedules computed for this client.
    let mut c = Client::new(&path);
    for line in LINES_A {
        c.send(line);
    }
    let (results_c, errors_c) = c.drain();
    assert!(errors_c.is_empty(), "{errors_c:?}");
    assert_eq!(joined(&results_c), expected_a, "warm client must match the cold batch");

    c.send(r#"{"ctl":"shutdown"}"#);
    assert_eq!(c.recv().as_deref(), Some(r#"{"ok":"shutting down"}"#));
    assert_eq!(c.recv(), None, "daemon closes the socket after the drain");

    let (summary, computed) = daemon.join().unwrap();
    assert!(computed > 0, "the cold submissions computed schedules");
    assert_eq!(summary.total_failed(), 0);
    assert_eq!(
        summary.total_results(),
        LINES_A.len() * 2 + 1 + 2 // A + C (3 results each), B (1 + 2-point sweep)
    );
    let c2 = summary
        .clients
        .iter()
        .find(|c| c.name == "c2")
        .expect("warm client session in the shutdown summary");
    assert_eq!(c2.counters.schedules_computed, 0, "warm client computes nothing");
    assert_eq!(c2.counters.results, LINES_A.len());
    assert_eq!(c2.counters.rejected, 0);
    assert!(!path.exists(), "socket file removed on shutdown");
}

#[test]
fn tracing_enabled_daemon_still_matches_batch_bytes() {
    // The observability invariant end to end: a daemon with event
    // recording on answers with exactly the bytes an untraced cold
    // batch produces. (The flag is process-global; the invariant itself
    // — tracing never changes result bytes — is what keeps concurrent
    // tests in this binary unaffected.)
    let path = socket_path("traced");
    let expected = batch_baseline(&LINES_A);
    memsched::obs::set_enabled(true);
    let daemon = spawn_daemon(path.clone(), ServeOptions::default(), 2);
    let mut c = Client::new(&path);
    for line in LINES_A {
        c.send(line);
    }
    let (results, errors) = c.drain();
    c.send(r#"{"ctl":"shutdown"}"#);
    assert_eq!(c.recv().as_deref(), Some(r#"{"ok":"shutting down"}"#));
    let (summary, _) = daemon.join().unwrap();
    memsched::obs::set_enabled(false);
    let recs = memsched::obs::drain();
    assert!(errors.is_empty(), "{errors:?}");
    assert_eq!(joined(&results), expected, "traced daemon must match the untraced batch");
    assert_eq!(summary.total_failed(), 0);
    assert!(!recs.is_empty(), "the traced daemon recorded no events");
}

#[test]
fn stats_request_reports_counters_and_sessions() {
    use memsched::ser::json::Value;

    let path = socket_path("stats");
    let daemon = spawn_daemon(path.clone(), ServeOptions::default(), 1);

    let mut c = Client::new(&path);
    for line in LINES_A {
        c.send(line);
    }
    // The stats item queues behind the submissions, so the reply
    // observes all three results.
    c.send(r#"{"ctl":"stats"}"#);
    let (mut frames, mut stats_frame) = (0usize, None);
    loop {
        let frame = c.recv().expect("connection closed before the stats reply");
        if frame.starts_with("{\"id\":") {
            frames += 1;
            continue;
        }
        stats_frame = Some(frame);
        break;
    }
    assert_eq!(frames, LINES_A.len(), "stats reply must queue behind the submissions");
    let reply = Value::parse(&stats_frame.unwrap()).expect("stats reply must be JSON");
    let stats = reply.get("stats").expect("reply wraps a stats object");
    assert_eq!(stats.get("schema"), Some(&Value::Number(2.0)));
    assert!(stats.get("tracing").is_some());
    let counters = stats.get("counters").expect("global counters object");
    // Three submissions, one duplicate: two schedules computed, one reuse.
    assert_eq!(counters.get("schedules_computed"), Some(&Value::Number(2.0)));
    assert_eq!(counters.get("schedule_reuse_hits"), Some(&Value::Number(1.0)));
    let Some(Value::Array(clients)) = stats.get("clients") else {
        panic!("stats reply must list client sessions");
    };
    assert_eq!(clients.len(), 1, "one live session at stats time");
    let session = &clients[0];
    assert_eq!(session.get("name").and_then(Value::as_str), Some("c0"));
    assert_eq!(session.get("results"), Some(&Value::Number(3.0)));
    let session_counters = session.get("counters").expect("per-session counters");
    assert_eq!(session_counters.get("schedules_computed"), Some(&Value::Number(2.0)));

    c.send(r#"{"ctl":"shutdown"}"#);
    assert_eq!(c.recv().as_deref(), Some(r#"{"ok":"shutting down"}"#));
    let (summary, _) = daemon.join().unwrap();
    assert_eq!(summary.total_results(), LINES_A.len());
}

#[test]
fn garbage_and_oversized_frames_fail_per_connection_not_the_daemon() {
    let path = socket_path("defense");
    // A tight payload cap so an ordinary string trips the oversize path.
    let opts = ServeOptions { max_frame_bytes: 64, ..ServeOptions::default() };
    let daemon = spawn_daemon(path.clone(), opts, 1);

    // Client 1 writes raw garbage (not a frame): it gets an error frame
    // and its connection is dropped — the process survives.
    {
        let mut garbage = connect(&path);
        use std::io::Write as _;
        garbage.write_all(b"definitely not a frame").unwrap();
        garbage.flush().unwrap();
        let mut c = Client { stream: garbage };
        let err = c.recv().expect("an error frame before the hangup");
        assert!(err.contains("error"), "{err}");
        assert_eq!(c.recv(), None, "unframable connection is dropped");
    }

    // Client 2, on the same daemon: an oversized frame is rejected with
    // a structured error, and the *same connection* keeps working.
    let mut c = Client::new(&path);
    let big = format!(r#"{{"model":"{}"}}"#, "x".repeat(128));
    c.send(&big);
    let err = c.recv().expect("oversize rejection frame");
    assert!(err.contains("exceeds"), "{err}");
    c.send(r#"{"model":"bacass","input":1,"seed":5}"#);
    let (results, errors) = c.drain();
    assert!(errors.is_empty(), "{errors:?}");
    assert_eq!(joined(&results), batch_baseline(&[r#"{"model":"bacass","input":1,"seed":5}"#]));

    // A malformed-but-framed job line answers with an error frame and
    // the connection still drains cleanly.
    c.send(r#"{"model":"bacass","typo":1}"#);
    let (results, errors) = c.drain();
    assert!(results.is_empty());
    assert_eq!(errors.len(), 1);
    assert!(errors[0].contains("unknown job field"), "{}", errors[0]);

    c.send(r#"{"ctl":"shutdown"}"#);
    assert_eq!(c.recv().as_deref(), Some(r#"{"ok":"shutting down"}"#));
    let (summary, _) = daemon.join().unwrap();
    // Only client 2 ran jobs; the garbage connection contributed no
    // sessions' results.
    assert_eq!(summary.total_results(), 1);
    assert_eq!(summary.total_failed(), 0);
}

#[test]
fn shutdown_drains_queued_work_before_returning() {
    let path = socket_path("drainout");
    let daemon = spawn_daemon(path.clone(), ServeOptions::default(), 2);
    let expected = batch_baseline(&LINES_A);

    // Queue work and request shutdown immediately — no drain barrier.
    // Every already-admitted job must still produce its result frame.
    let mut c = Client::new(&path);
    for line in LINES_A {
        c.send(line);
    }
    c.send(r#"{"ctl":"shutdown"}"#);
    let mut results = Vec::new();
    loop {
        let Some(frame) = c.recv() else {
            break; // daemon drained, answered, and hung up
        };
        if frame.starts_with("{\"id\":") {
            results.push(frame);
        } else {
            assert_eq!(frame, r#"{"ok":"shutting down"}"#, "unexpected frame");
        }
    }
    assert_eq!(joined(&results), expected, "queued work drains through shutdown");

    let (summary, _) = daemon.join().unwrap();
    assert_eq!(summary.total_results(), LINES_A.len());
    assert_eq!(summary.total_failed(), 0);
}
