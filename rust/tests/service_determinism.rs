//! Cross-thread determinism of the scheduling service: the same seeded
//! job batch must produce **byte-identical** JSONL for any worker count
//! (schedules, makespans, simulation outcomes, cache flags), and
//! duplicate jobs must be served from the schedule cache.

use std::sync::Arc;

use memsched::experiments::{SuiteScale, WorkloadSpec};
use memsched::platform::presets::small_cluster;
use memsched::scheduler::Algorithm;
use memsched::service::{
    self, ClusterSpec, Job, JobSource, SchedulingService, ScoreThreadSpec, ServiceConfig, SimJob,
};
use memsched::simulator::SimMode;

/// A seeded 22-job batch: 4 workloads × 4 algorithms, two simulation
/// jobs, and four exact duplicates.
fn batch() -> Vec<Job> {
    let cluster = ClusterSpec::Inline(Arc::new(small_cluster()));
    let spec = |family: &str, input: usize, seed: u64| {
        JobSource::Generated(WorkloadSpec { family: family.into(), size: None, input, seed })
    };
    let mut jobs = Vec::new();
    for (family, input, seed) in
        [("chipseq", 1, 3u64), ("eager", 2, 4), ("bacass", 0, 5), ("methylseq", 1, 6)]
    {
        for &algo in Algorithm::all() {
            jobs.push(Job::new(spec(family, input, seed), cluster.clone()).with_algo(algo));
        }
    }
    // Runtime-simulation jobs (both modes) on one of the workloads.
    for mode in [SimMode::Recompute, SimMode::FollowStatic] {
        jobs.push(
            Job::new(spec("chipseq", 1, 3), cluster.clone())
                .with_algo(Algorithm::HeftmBl)
                .with_sim(SimJob { mode, sigma: 0.1, seed: 11 }),
        );
    }
    // Exact duplicates sprinkled in (dedupe targets).
    let d0 = jobs[0].clone();
    let d5 = jobs[5].clone();
    let d16 = jobs[16].clone();
    jobs.push(d0);
    jobs.push(d5);
    jobs.push(d16.clone());
    jobs.push(d16);
    assert!(jobs.len() >= 16, "acceptance requires a ≥16-job batch");
    jobs
}

fn run(workers: usize) -> (Vec<u8>, usize, usize) {
    let service = SchedulingService::new(workers);
    let results = service.run_batch(batch());
    assert!(results.iter().all(|r| r.error.is_none()), "batch must succeed");
    let stats = service.cache_stats();
    (service::to_jsonl(&results).into_bytes(), stats.computed, stats.hits())
}

#[test]
fn jsonl_bytes_identical_for_any_worker_count() {
    let (bytes1, computed1, hits1) = run(1);
    for workers in [2, 4, 8] {
        let (bytes_n, computed_n, hits_n) = run(workers);
        assert_eq!(
            bytes1, bytes_n,
            "JSONL diverged between --jobs 1 and --jobs {workers}"
        );
        // Cache behaviour is deterministic too, not just the output.
        assert_eq!(computed1, computed_n, "computed-schedule count diverged at {workers}");
        assert_eq!(hits1, hits_n, "cache-hit count diverged at {workers}");
    }
}

#[test]
fn duplicate_jobs_are_cache_hits() {
    let service = SchedulingService::new(4);
    let jobs = batch();
    let n = jobs.len();
    let results = service.run_batch(jobs);
    // The four appended duplicates dedupe against their originals; the
    // FollowStatic sim job also shares the HEFTM-BL schedule computation.
    let dup_results = &results[n - 4..];
    assert!(dup_results.iter().all(|r| r.cache_hit), "duplicates must be cache hits");
    assert!(service.cache_stats().hits() >= 4);
    // Deduped jobs report the exact payload of their originals.
    assert_eq!(results[0].makespan, results[n - 4].makespan);
    assert_eq!(results[0].fingerprint, results[n - 4].fingerprint);
    assert_eq!(results[5].makespan, results[n - 3].makespan);
}

#[test]
fn score_threads_do_not_change_jsonl_bytes() {
    // Intra-schedule parallel scoring (the second parallelism axis) must
    // be invisible in the wire format, exactly like the worker count.
    let baseline = SchedulingService::new(2);
    let r_base = baseline.run_batch(batch());
    for score_threads in [2, 8] {
        let svc = SchedulingService::from_config(ServiceConfig {
            workers: 2,
            score: ScoreThreadSpec::Fixed(score_threads),
            ..ServiceConfig::default()
        })
        .unwrap();
        let r = svc.run_batch(batch());
        assert_eq!(
            service::to_jsonl(&r_base),
            service::to_jsonl(&r),
            "JSONL diverged at --score-threads {score_threads}"
        );
        assert_eq!(baseline.cache_stats().computed, svc.cache_stats().computed);
    }
}

#[test]
fn tracing_does_not_change_jsonl_bytes() {
    // Observability is a side channel: enabling event recording must not
    // perturb the result stream by a single byte. (Each integration test
    // binary is its own process, so flipping the process-global flag here
    // cannot leak into other test files; within this binary the flag is
    // restored before the test ends.)
    let (baseline, computed, hits) = run(2);
    memsched::obs::set_enabled(true);
    let traced = run(2);
    memsched::obs::set_enabled(false);
    let recs = memsched::obs::drain();
    assert_eq!(baseline, traced.0, "JSONL diverged with tracing enabled");
    assert_eq!(computed, traced.1);
    assert_eq!(hits, traced.2);
    // The run actually produced events — otherwise this test proves nothing.
    assert!(!recs.is_empty(), "tracing-enabled run recorded no events");
    assert!(!memsched::obs::metrics_records(&recs).is_empty());
}

#[test]
fn suite_grid_byte_deterministic_through_the_service() {
    // The CLI `batch --suite smoke` path: the experiments grid itself
    // must be byte-deterministic across worker counts.
    let jobs = |_: ()| {
        memsched::experiments::static_suite_jobs(
            SuiteScale::Smoke,
            42,
            &ClusterSpec::Inline(Arc::new(small_cluster())),
        )
    };
    let s1 = SchedulingService::new(1);
    let r1 = s1.run_batch(jobs(()));
    let s4 = SchedulingService::new(4);
    let r4 = s4.run_batch(jobs(()));
    assert_eq!(service::to_jsonl(&r1), service::to_jsonl(&r4));
    assert_eq!(
        r1.len(),
        10 * Algorithm::all().len(),
        "smoke grid: 10 workloads × every standalone algorithm"
    );
}
