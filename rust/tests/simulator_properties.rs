//! Property-based tests for the runtime system (discrete-event simulator).
//!
//! Invariants:
//!  1. simulations terminate (complete or fail with a reason) — no stalls;
//!  2. finish times respect dependencies when completed;
//!  3. identical seeds → identical outcomes (both modes);
//!  4. recompute mode completes whenever follow-static does (it only adds
//!     options);
//!  5. zero deviation in follow-static mode completes every valid
//!     schedule.

use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};
use memsched::testing::{check, random_cluster, random_dag};

const CASES: usize = 40;

#[test]
fn simulations_always_terminate_coherently() {
    check(CASES, 0x51A1, |rng| {
        let wf = random_dag(rng, 60);
        let cluster = random_cluster(rng);
        let seed = rng.next_u64();
        for &algo in Algorithm::all() {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            for mode in [SimMode::FollowStatic, SimMode::Recompute] {
                let cfg = SimConfig::new(mode, DeviationModel::new(0.1, seed));
                let out = simulate(&wf, &cluster, &s, &cfg);
                if !out.completed && out.failure.is_none() {
                    return Err(format!("{algo:?} {mode:?}: stalled without failure"));
                }
                if out.completed && out.started != wf.num_tasks() {
                    return Err(format!("{algo:?} {mode:?}: completed but started {} of {}",
                        out.started, wf.num_tasks()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn completed_runs_respect_dependencies() {
    check(CASES, 0x52B2, |rng| {
        let wf = random_dag(rng, 50);
        let cluster = random_cluster(rng);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.1, rng.next_u64()));
        let out = simulate(&wf, &cluster, &s, &cfg);
        if out.completed {
            for e in wf.edges() {
                if out.finish_times[e.dst] < out.finish_times[e.src] - 1e-6 {
                    return Err(format!("edge ({}, {}) finished out of order", e.src, e.dst));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn identical_seeds_identical_outcomes() {
    check(CASES, 0x53C3, |rng| {
        let wf = random_dag(rng, 40);
        let cluster = random_cluster(rng);
        let seed = rng.next_u64();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBlc).policy(EvictionPolicy::LargestFirst).run();
        for mode in [SimMode::FollowStatic, SimMode::Recompute] {
            let cfg = SimConfig::new(mode, DeviationModel::new(0.1, seed));
            let a = simulate(&wf, &cluster, &s, &cfg);
            let b = simulate(&wf, &cluster, &s, &cfg);
            if a.completed != b.completed || (a.completed && a.makespan != b.makespan) {
                return Err(format!("{mode:?}: nondeterministic outcome"));
            }
        }
        Ok(())
    });
}

#[test]
fn recompute_dominates_follow_static_on_completion() {
    check(CASES, 0x54D4, |rng| {
        let wf = random_dag(rng, 50);
        let cluster = random_cluster(rng);
        let seed = rng.next_u64();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run();
        if !s.valid {
            return Ok(());
        }
        let dev = DeviationModel::new(0.1, seed);
        let stat = simulate(&wf, &cluster, &s, &SimConfig::new(SimMode::FollowStatic, dev));
        let dynr = simulate(&wf, &cluster, &s, &SimConfig::new(SimMode::Recompute, dev));
        if stat.completed && !dynr.completed {
            return Err(format!(
                "follow-static completed but recompute failed: {:?}",
                dynr.failure
            ));
        }
        Ok(())
    });
}

#[test]
fn zero_deviation_completes_all_valid_schedules() {
    check(CASES, 0x55E5, |rng| {
        let wf = random_dag(rng, 50);
        let cluster = random_cluster(rng);
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmBlc, Algorithm::HeftmMm] {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if !s.valid {
                continue;
            }
            let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::none(1));
            let out = simulate(&wf, &cluster, &s, &cfg);
            if !out.completed {
                return Err(format!(
                    "{algo:?}: zero-deviation execution failed: {:?}",
                    out.failure
                ));
            }
        }
        Ok(())
    });
}
