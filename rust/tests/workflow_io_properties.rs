//! Randomized property tests for `workflow::io`: generator-produced
//! workflows round-trip through `to_json → from_json` to an identical
//! DAG, and malformed documents (duplicate task names) are rejected.
//! Complements the small hand-written graphs in `io.rs`'s unit tests.

use memsched::generator::{self, models};
use memsched::ser::json::Value;
use memsched::testing::{check, random_dag};
use memsched::traces::{self, HistoricalData, TraceConfig};
use memsched::workflow::io::{from_json, to_json};
use memsched::workflow::Workflow;

/// Exact structural equality: tasks (name, type, work, memory), edge
/// endpoints, and edge data sizes. Weights are compared bit-exactly —
/// the JSON number writer emits shortest-roundtrip representations, so
/// serialization must not lose a single ULP.
fn assert_same_dag(a: &Workflow, b: &Workflow) -> Result<(), String> {
    if a.name != b.name {
        return Err(format!("name: {} vs {}", a.name, b.name));
    }
    if a.num_tasks() != b.num_tasks() || a.num_edges() != b.num_edges() {
        return Err(format!(
            "shape: {}t/{}e vs {}t/{}e",
            a.num_tasks(),
            a.num_edges(),
            b.num_tasks(),
            b.num_edges()
        ));
    }
    for (i, (ta, tb)) in a.tasks().iter().zip(b.tasks()).enumerate() {
        if ta != tb {
            return Err(format!("task {i}: {ta:?} vs {tb:?}"));
        }
    }
    for (i, (ea, eb)) in a.edges().iter().zip(b.edges()).enumerate() {
        if ea.src != eb.src || ea.dst != eb.dst || ea.data.to_bits() != eb.data.to_bits() {
            return Err(format!("edge {i}: {ea:?} vs {eb:?}"));
        }
    }
    Ok(())
}

#[test]
fn random_dags_roundtrip_exactly() {
    check(60, 0x10_CAFE, |rng| {
        let wf = random_dag(rng, 120);
        let wf2 = from_json(&to_json(&wf)).map_err(|e| format!("reparse failed: {e:#}"))?;
        assert_same_dag(&wf, &wf2)
    });
}

#[test]
fn generator_workflows_with_bound_weights_roundtrip() {
    // The full production pipeline: model expansion (and WfGen-like
    // scaling) + historical-trace weight binding, then through JSON.
    let mut seed = 1u64;
    for model in models::all_models() {
        for size in [None, Some(200)] {
            let graph = match size {
                Some(n) => generator::scale_to(&model, n, seed).unwrap(),
                None => generator::expand(&model, 7).unwrap(),
            };
            let data = HistoricalData::synthesize(
                &traces::task_types(&graph),
                &TraceConfig::default(),
                seed,
            );
            let wf = traces::bind_weights(&graph, &data, 2);
            let wf2 = from_json(&to_json(&wf)).unwrap();
            assert_same_dag(&wf, &wf2).unwrap();
            // The round-tripped DAG must also still be a valid DAG.
            assert!(wf2.is_topological_order(&wf2.topological_order()));
            seed += 1;
        }
    }
}

#[test]
fn text_level_roundtrip_is_stable() {
    // serialize → print → parse → deserialize → serialize again: the two
    // JSON texts must be identical (no drift across passes).
    check(20, 0xBEEF, |rng| {
        let wf = random_dag(rng, 60);
        let text1 = to_json(&wf).to_string_pretty();
        let v = Value::parse(&text1).map_err(|e| e.to_string())?;
        let wf2 = from_json(&v).map_err(|e| format!("{e:#}"))?;
        let text2 = to_json(&wf2).to_string_pretty();
        if text1 != text2 {
            return Err("serialized texts diverged across a roundtrip".into());
        }
        Ok(())
    });
}

#[test]
fn duplicate_task_names_rejected() {
    let text = r#"{
        "name": "dup",
        "tasks": [
            {"name": "a", "work": 1, "memory": 1},
            {"name": "b", "work": 1, "memory": 1},
            {"name": "a", "work": 2, "memory": 2}
        ],
        "edges": []
    }"#;
    let err = from_json(&Value::parse(text).unwrap()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("duplicate"), "unexpected error: {msg}");
    assert!(msg.contains('a'), "should name the offending task: {msg}");
}

#[test]
fn duplicate_names_rejected_regardless_of_edge_wiring() {
    // Name-keyed edges resolve to the *last* duplicate before validation
    // runs; the build must still fail on the duplicate itself.
    let text = r#"{
        "name": "dup2",
        "tasks": [
            {"name": "x", "work": 1, "memory": 1},
            {"name": "x", "work": 1, "memory": 1}
        ],
        "edges": [ {"src": 0, "dst": 1, "data": 1} ]
    }"#;
    assert!(from_json(&Value::parse(text).unwrap()).is_err());
}
