//! Offline in-tree stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this shim provides
//! the subset of the real API the repository uses: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error values carry a context chain of plain strings;
//! `{e}` prints the outermost message (as real anyhow does) and `{e:#}`
//! prints the full chain separated by `": "`.

use std::fmt;

/// A string-chained error value. The first entry is the outermost
/// context; the root cause is last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: any std error converts, and `Error` itself does NOT
// implement `std::error::Error` (which is what makes this blanket impl
// coherent next to the reflexive `From<T> for T`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "outer".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: gone");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "not ok");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/nonexistent/anyhow-shim-test")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
    }
}
