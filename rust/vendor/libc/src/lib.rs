//! Offline in-tree stand-in for the `libc` crate: only the symbols the
//! `memsched` binary actually uses (restoring default SIGPIPE behaviour).

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type sighandler_t = usize;

/// Default signal handling.
pub const SIG_DFL: sighandler_t = 0;
/// Interrupt from keyboard (Linux signal number).
pub const SIGINT: c_int = 2;
/// Broken pipe (Linux signal number).
pub const SIGPIPE: c_int = 13;
/// Termination request (Linux signal number).
pub const SIGTERM: c_int = 15;

extern "C" {
    /// `signal(2)` from the platform C library.
    pub fn signal(signum: c_int, handler: sighandler_t) -> sighandler_t;
}
