//! Offline in-tree stand-in for the `log` facade: the level macros print
//! straight to stderr (no registry, no filtering). Sufficient for the
//! handful of diagnostic call sites in this repository.

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { eprintln!("[error] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { eprintln!("[warn] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { eprintln!("[info] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { eprintln!("[debug] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { eprintln!("[trace] {}", format!($($arg)*)) };
}
