//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate wraps the PJRT C API and executes AOT-compiled HLO
//! modules. That native runtime is not present in this offline build, so
//! this stub reproduces exactly the type surface `memsched::runtime`
//! consumes and fails at client construction with a clear error. All
//! callers already handle load failures gracefully (the XLA scorer and
//! predictor are optional accelerators; the native Rust paths are the
//! defaults), so the rest of the system is unaffected.

use std::fmt;

/// Error type matching the real crate's `std::error::Error` behaviour.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error { msg: "XLA/PJRT runtime is not available in this offline build (stub xla crate)".into() }
}

/// Element types of XLA literals (only the variant the bridge uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
}

/// PJRT client handle. Construction always fails in the stub.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation built from an HLO proto.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable on a PJRT device.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// A device buffer produced by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// A host-side literal value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
    }
}
